"""FaultInjector — the wire-level half of the fault harness.

The injector installs as a module-level hook inside :mod:`repro.core.wire`
(:func:`install` / :func:`uninstall`).  Only sockets *registered* with the
injector are in scope — ``wire.connect`` registers every socket it creates
while a hook is installed, optionally filtered by an address ``scope`` —
so server-side accepted sockets (and any connection opened before the
harness went up) pass through untouched.  That is the "injectable conn
factory": the faulty behaviour follows the client connections created
under the plan, deterministically.

Semantics per kind (see :mod:`repro.faults.plan` for the schedule DSL):

* ``drop`` / ``partition`` close the socket and raise ``ConnectionError``
  from the send call.  A *silent* frame drop is deliberately not offered:
  the stripe protocol matches acks FIFO against in-flight frames, so a
  swallowed frame would desync the stream rather than exercise recovery —
  on a stream transport, "the frame was lost" means "the link broke".
* ``partition`` additionally fails every subsequent ``wire.connect`` to
  the matched peer for ``duration_s`` seconds (reconnect storms hit the
  wall the way a real network partition provides).
* ``corrupt`` flips bytes in a **copy** of the payload — the caller's
  pinned buffer (the journal's replay source) is never touched.
* ``delay`` sleeps before the frame leaves; ``dup`` sends it twice.

All mutable state lives behind one leaf lock; sleeps and socket closes
happen outside it.
"""
from __future__ import annotations

import random
import threading
import time
import weakref
from contextlib import contextmanager
from typing import Optional, Sequence

from repro.faults.plan import FaultPlan, FaultRule

# _GUARDED_BY (reprolint): all of FaultInjector._match_counts,
# FaultInjector._partitions, FaultInjector.fired: FaultInjector._lock

_GUARDED_BY = {
    "FaultInjector._match_counts": "FaultInjector._lock",
    "FaultInjector._partitions": "FaultInjector._lock",
    "FaultInjector.fired": "FaultInjector._lock",
}


def _sever(sock) -> None:
    try:
        sock.close()
    except OSError:
        pass


class FaultInjector:
    """Seeded, rule-driven traffic mangler for registered sockets."""

    def __init__(self, plan: FaultPlan,
                 scope: Optional[Sequence[str]] = None):
        self.plan = plan
        self._scope = tuple(scope) if scope else None
        self._rng = random.Random(plan.seed)
        self._lock = threading.Lock()
        self._match_counts: dict[int, int] = {}
        self._partitions: list[tuple[Optional[str], float]] = []
        self._socks: "weakref.WeakKeyDictionary" = weakref.WeakKeyDictionary()
        self.fired: dict[str, int] = {}

    # -- scope ----------------------------------------------------------
    def register(self, sock, addr: str) -> None:
        """Bring one connection into scope (called by ``wire.connect``)."""
        if self._scope is not None and \
                not any(s in addr for s in self._scope):
            return
        self._socks[sock] = addr

    def addr_of(self, sock) -> Optional[str]:
        return self._socks.get(sock)

    # -- manual controls (tests) ---------------------------------------
    def partition(self, peer: Optional[str], duration_s: float) -> None:
        """Start a partition by hand (tests that don't want a trigger
        frame)."""
        until = time.monotonic() + duration_s
        with self._lock:
            self._partitions.append((peer, until))
            self.fired["partition"] = self.fired.get("partition", 0) + 1

    def heal(self) -> None:
        """Lift every active partition immediately."""
        with self._lock:
            self._partitions.clear()

    # -- hook points (called from repro.core.wire) ---------------------
    def check_connect(self, addr: str) -> None:
        now = time.monotonic()
        with self._lock:
            self._partitions = [(p, u) for p, u in self._partitions
                                if u > now]
            for pat, _until in self._partitions:
                if pat is None or pat in addr:
                    raise ConnectionError(
                        f"fault-injected partition: {addr} unreachable")

    def on_send(self, sock, frames):
        """Transform outgoing ``(header, payload)`` frames; may sleep,
        sever + raise, duplicate, or corrupt (a copy of) payloads."""
        addr = self._socks.get(sock)
        if addr is None:
            return frames
        out = []
        for header, payload in frames:
            rule = self._decide(addr, header)
            if rule is None:
                out.append((header, payload))
                continue
            kind = rule.kind
            if kind == "drop":
                _sever(sock)
                raise ConnectionError(
                    f"fault-injected drop (op={header.get('op')}, "
                    f"peer={addr})")
            if kind == "partition":
                until = time.monotonic() + rule.duration_s
                with self._lock:
                    self._partitions.append((rule.peer or addr, until))
                _sever(sock)
                raise ConnectionError(
                    f"fault-injected partition (peer={addr}, "
                    f"{rule.duration_s}s)")
            if kind == "delay":
                time.sleep(rule.delay_s)
                out.append((header, payload))
            elif kind == "dup":
                out.append((header, payload))
                out.append((header, payload))
            elif kind == "corrupt":
                out.append((header, self._corrupt(payload, rule.flips)))
        return out

    def on_recv(self, sock, header) -> None:
        """Receive-side hook: only ``delay`` and ``drop`` make sense once
        the bytes already arrived intact."""
        addr = self._socks.get(sock)
        if addr is None:
            return
        rule = self._decide(addr, header, kinds=("delay", "drop"))
        if rule is None:
            return
        if rule.kind == "drop":
            _sever(sock)
            raise ConnectionError(
                f"fault-injected recv drop (op={header.get('op')})")
        time.sleep(rule.delay_s)

    # -- internals ------------------------------------------------------
    def _decide(self, addr: str, header: dict,
                kinds: Optional[tuple] = None) -> Optional[FaultRule]:
        op = header.get("op")
        with self._lock:
            for rule in self.plan.wire_rules:
                if kinds is not None and rule.kind not in kinds:
                    continue
                if not rule.matches(op, addr):
                    continue
                key = id(rule)
                c = self._match_counts[key] = \
                    self._match_counts.get(key, 0) + 1
                if rule.nth is not None:
                    fire = (c == rule.nth)
                elif rule.every is not None:
                    fire = (c % rule.every == 0)
                else:
                    fire = rule.prob > 0 and self._rng.random() < rule.prob
                if fire:
                    self.fired[rule.kind] = self.fired.get(rule.kind, 0) + 1
                    return rule
        return None

    def _corrupt(self, payload, flips: int):
        parts = (payload if isinstance(payload, (list, tuple))
                 else [] if payload is None else [payload])
        buf = bytearray()
        for p in parts:
            buf += bytes(memoryview(p).cast("B"))
        if not buf:
            return payload
        with self._lock:
            idxs = [self._rng.randrange(len(buf))
                    for _ in range(max(1, flips))]
        for i in idxs:
            buf[i] ^= 0xFF
        return buf


# -- installation -------------------------------------------------------

def install(plan: FaultPlan,
            scope: Optional[Sequence[str]] = None) -> FaultInjector:
    """Build an injector for ``plan`` and hook it into the wire layer."""
    from repro.core import wire
    inj = FaultInjector(plan, scope=scope)
    wire.set_fault_injector(inj)
    return inj


def uninstall() -> None:
    from repro.core import wire
    wire.set_fault_injector(None)


@contextmanager
def injected(plan: FaultPlan, scope: Optional[Sequence[str]] = None):
    """``with injected(plan) as inj:`` — scoped install/uninstall."""
    inj = install(plan, scope=scope)
    try:
        yield inj
    finally:
        uninstall()
