"""FaultPlan — the deterministic, seeded fault schedule (DESIGN.md §15).

A plan is a seed plus an ordered list of :class:`FaultRule`.  Each rule
names a fault ``kind`` and how it triggers:

  kind        effect at the wire boundary
  ----        ---------------------------
  drop        sever the connection instead of sending the frame (a
              silent frame drop would desync the FIFO ack protocol, so
              "drop" on a stream transport means "the link died here")
  delay       sleep ``delay_s`` before the frame goes out
  dup         send the frame twice (servers must dedup)
  corrupt     flip ``flips`` random bytes in a *copy* of the payload
  partition   fail every ``connect`` to the matched peer for
              ``duration_s`` (and sever the triggering connection)
  kill        scheduled process death: at ``at_s`` seconds after the
              scheduler starts, invoke the named ``target``'s kill hook
              (``staging:0``, ``savime:1``, ``gateway``, ...)

Trigger selection per matching frame: ``nth`` fires exactly on the n-th
match (1-based), ``every`` fires on every k-th match, otherwise ``prob``
fires with that probability from the plan's seeded RNG.  Matching is by
frame ``op`` (None = any) and peer address substring (None = any peer).

Plans are built in code (tests), or parsed from the compact spec string
the ``--faults`` launcher flag takes::

    seed=42;drop:op=stripe,prob=0.01;kill:target=staging:0,at_s=0.5

or from a JSON file (``--faults plan.json``) holding
``{"seed": 42, "rules": [{"kind": "drop", "op": "stripe", ...}]}``.
"""
from __future__ import annotations

import json
import os
from dataclasses import asdict, dataclass, field
from typing import Optional

KINDS = ("drop", "delay", "dup", "corrupt", "partition", "kill")

_FLOAT_KEYS = ("prob", "delay_s", "duration_s", "at_s")
_INT_KEYS = ("nth", "every", "flips")


@dataclass
class FaultRule:
    """One fault: what it does (``kind``) and when it fires."""

    kind: str
    op: Optional[str] = None          # frame op to match (None = any)
    peer: Optional[str] = None        # substring of the peer addr
    nth: Optional[int] = None         # fire on exactly the n-th match
    every: Optional[int] = None       # fire on every k-th match
    prob: float = 0.0                 # else: fire with this probability
    delay_s: float = 0.0              # kind=delay
    flips: int = 1                    # kind=corrupt
    duration_s: float = 0.25          # kind=partition
    at_s: float = 0.0                 # kind=kill
    target: Optional[str] = None      # kind=kill

    def __post_init__(self):
        if self.kind not in KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r} "
                             f"(expected one of {KINDS})")
        if self.kind == "kill" and not self.target:
            raise ValueError("kill rule requires target=")

    def matches(self, op: Optional[str], peer: Optional[str]) -> bool:
        if self.op is not None and op != self.op:
            return False
        if self.peer is not None and (peer is None or self.peer not in peer):
            return False
        return True


@dataclass
class FaultPlan:
    """Seeded RNG + rules; reusable across tests, launchers and benches."""

    seed: int = 0
    rules: list = field(default_factory=list)

    @property
    def kill_rules(self) -> list:
        return [r for r in self.rules if r.kind == "kill"]

    @property
    def wire_rules(self) -> list:
        return [r for r in self.rules if r.kind != "kill"]

    def encode(self) -> dict:
        return {"seed": self.seed, "rules": [asdict(r) for r in self.rules]}

    @classmethod
    def decode(cls, obj: dict) -> "FaultPlan":
        return cls(seed=int(obj.get("seed", 0)),
                   rules=[FaultRule(**r) for r in obj.get("rules", ())])

    @classmethod
    def parse(cls, spec: str) -> "FaultPlan":
        """Parse the ``--faults`` argument: a spec string or a JSON path."""
        spec = spec.strip()
        if spec.endswith(".json") or os.path.isfile(spec):
            with open(spec) as f:
                return cls.decode(json.load(f))
        seed, rules = 0, []
        for part in filter(None, (p.strip() for p in spec.split(";"))):
            if part.startswith("seed="):
                seed = int(part[5:])
                continue
            kind, _, argstr = part.partition(":")
            kwargs: dict = {}
            for kv in filter(None, (a.strip() for a in argstr.split(","))):
                k, _, v = kv.partition("=")
                if k in _FLOAT_KEYS:
                    kwargs[k] = float(v)
                elif k in _INT_KEYS:
                    kwargs[k] = int(v)
                elif k in ("op", "peer", "target"):
                    kwargs[k] = v
                else:
                    raise ValueError(f"unknown fault rule key {k!r} in "
                                     f"{part!r}")
            rules.append(FaultRule(kind=kind, **kwargs))
        return cls(seed=seed, rules=rules)
