"""HPC4e-like synthetic seismic wavefield (the paper's §4 dataset class).

The paper's experiment dataset: 500 trials of a 3D regular 201x501x501
velocity-field mesh (25e9 points, >100 GB). This generator produces the
same *kind* of data at configurable scale: a damped traveling-wavefront
velocity field evolved per time step — the producer side of the in-transit
pipeline in examples/ and benchmarks/.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class SeismicConfig:
    nx: int = 51
    ny: int = 126
    nz: int = 126
    n_sources: int = 4
    velocity: float = 0.18       # wavefront speed in grid units / step
    damping: float = 0.02
    seed: int = 0


class SeismicField:
    def __init__(self, cfg: SeismicConfig):
        self.cfg = cfg
        rng = np.random.default_rng(cfg.seed)
        self.sources = rng.uniform(0.1, 0.9, (cfg.n_sources, 3))
        self.amps = rng.uniform(0.5, 1.5, cfg.n_sources)
        gx = np.linspace(0, 1, cfg.nx)[:, None, None]
        gy = np.linspace(0, 1, cfg.ny)[None, :, None]
        gz = np.linspace(0, 1, cfg.nz)[None, None, :]
        self._grid = (gx, gy, gz)

    def step(self, t: int) -> np.ndarray:
        """Velocity field at time step t: superposed expanding shells."""
        c = self.cfg
        gx, gy, gz = self._grid
        field = np.zeros((c.nx, c.ny, c.nz), np.float64)
        r_t = c.velocity * (t + 1)
        for (sx, sy, sz), a in zip(self.sources, self.amps):
            r = np.sqrt((gx - sx) ** 2 + (gy - sy) ** 2 + (gz - sz) ** 2)
            shell = np.exp(-((r - r_t) ** 2) / (2 * 0.03 ** 2))
            field += a * np.exp(-c.damping * t) * shell
        return field

    def trial(self, n_steps: int):
        for t in range(n_steps):
            yield t, self.step(t)
