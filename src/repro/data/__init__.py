from repro.data.pipeline import DataConfig, SyntheticLM, device_put_batch  # noqa: F401
from repro.data.seismic import SeismicConfig, SeismicField  # noqa: F401
