"""Data pipeline: synthetic-but-structured LM batches + host->device feed.

Synthetic corpus: Zipf-distributed tokens with injected repeated n-grams so
a real model shows a falling loss within a few hundred steps (used by the
end-to-end example). Batches are built per host and placed as globally
sharded arrays (make_array_from_process_local_data) — multi-host ready,
single-host exercised here.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.train.sharding import batch_shardings


@dataclasses.dataclass
class DataConfig:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    zipf_a: float = 1.3
    n_motifs: int = 64          # repeated n-grams (learnable structure)
    motif_len: int = 8
    motif_rate: float = 0.3
    n_prefix: int = 0
    d_model: int = 0            # for prefix_embed stub (vlm/audio)


class SyntheticLM:
    def __init__(self, cfg: DataConfig):
        self.cfg = cfg
        self.rng = np.random.default_rng(cfg.seed)
        v = max(cfg.vocab_size - 2, 2)
        # zipf over a permuted alphabet
        ranks = np.arange(1, v + 1, dtype=np.float64)
        p = ranks ** -cfg.zipf_a
        self.p = p / p.sum()
        self.perm = self.rng.permutation(v)
        self.motifs = self.rng.integers(
            0, v, size=(cfg.n_motifs, cfg.motif_len))

    def _sample_tokens(self, n: int) -> np.ndarray:
        c = self.cfg
        toks = self.perm[np.searchsorted(
            np.cumsum(self.p), self.rng.random(n), side="right").clip(0, len(self.p) - 1)]
        # splice motifs at random positions
        n_splice = int(n * c.motif_rate / c.motif_len)
        if n_splice:
            pos = self.rng.integers(0, max(n - c.motif_len, 1), n_splice)
            ids = self.rng.integers(0, c.n_motifs, n_splice)
            for p_, i_ in zip(pos, ids):
                toks[p_:p_ + c.motif_len] = self.motifs[i_]
        return toks.astype(np.int32)

    def batches(self) -> Iterator[dict[str, np.ndarray]]:
        c = self.cfg
        while True:
            flat = self._sample_tokens(c.global_batch * (c.seq_len + 1))
            flat = flat.reshape(c.global_batch, c.seq_len + 1)
            batch = {
                "tokens": flat[:, :-1],
                "targets": flat[:, 1:],
                "loss_mask": np.ones((c.global_batch, c.seq_len), np.float32),
            }
            if c.n_prefix:
                batch["prefix_embed"] = self.rng.standard_normal(
                    (c.global_batch, c.n_prefix, c.d_model)).astype(np.float32) * 0.02
            yield batch


def device_put_batch(batch: dict, mesh, rules) -> dict:
    shardings = batch_shardings(mesh, rules, batch)
    return {k: jax.device_put(v, shardings[k]) for k, v in batch.items()}
