"""recurrentgemma-9b — Griffin: RG-LRU + local attention, attn:rglru = 1:2.

[arXiv:2402.19427; unverified] 38L d_model=4096 16H (GQA kv=1, MQA)
d_ff=12288 vocab=256000, window 2048, pattern (R, R, A).
"""
from repro.configs.base import ArchConfig, RGLRUConfig

CONFIG = ArchConfig(
    name="recurrentgemma-9b",
    family="hybrid",
    n_layers=38,
    d_model=4096,
    n_heads=16,
    n_kv_heads=1,
    head_dim=256,
    d_ff=12288,
    vocab_size=256_000,
    layer_pattern=("rglru", "rglru", "local"),
    attn_window=2048,
    rglru=RGLRUConfig(d_rnn=4096, d_conv=4, block_width=256),
    tie_embeddings=True,
    scale_embeddings=True,
    mlp_act="gelu",
)
