"""gemma2-27b — alternating local/global attention with logit soft-capping.

[arXiv:2408.00118; hf] 46L d_model=4608 32H (GQA kv=16) d_ff=36864
vocab=256000, window 4096, attn softcap 50, final-logit softcap 30,
query scale 1/sqrt(d_model/n_heads)=1/sqrt(144).
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="gemma2-27b",
    family="dense",
    n_layers=46,
    d_model=4608,
    n_heads=32,
    n_kv_heads=16,
    head_dim=128,
    d_ff=36864,
    vocab_size=256_000,
    layer_pattern=("local", "global"),
    attn_window=4096,
    attn_softcap=50.0,
    logit_softcap=30.0,
    query_scale=(4608 / 32) ** -0.5,
    tie_embeddings=True,
    scale_embeddings=True,
    post_norms=True,
    mlp_act="gelu",
)
