"""llama4-maverick-400b-a17b — MoE 128e top-1, early fusion (frontend stub).

[hf:meta-llama/Llama-4-Scout-17B-16E; unverified] Spec: 48L d_model=5120 40H
(GQA kv=8) d_ff=8192 vocab=202048, MoE 128e top-1. Interpreted per the released
Maverick layout: MoE every other layer (interleave step 2) with an always-on
shared expert — this reproduces ~400B total / ~17B active.
"""
from repro.configs.base import ArchConfig, MoEConfig

CONFIG = ArchConfig(
    name="llama4-maverick-400b-a17b",
    family="moe",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab_size=202_048,
    layer_pattern=("dense", "moe"),
    moe=MoEConfig(n_experts=128, top_k=1, d_expert=8192, shared_expert=True),
    rope_theta=500_000.0,
    mlp_act="silu",
)
