"""arctic-480b — 128 experts top-2 + dense residual MLP in every layer.

[hf:Snowflake/snowflake-arctic-base; hf] 35L d_model=7168 56H (GQA kv=8)
d_ff=4864 vocab=32000, MoE 128e top-2, dense-MoE hybrid residual.
"""
from repro.configs.base import ArchConfig, MoEConfig

CONFIG = ArchConfig(
    name="arctic-480b",
    family="moe",
    n_layers=35,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    head_dim=128,
    d_ff=4864,
    vocab_size=32_000,
    layer_pattern=("moe",),
    moe=MoEConfig(n_experts=128, top_k=2, d_expert=4864, dense_residual=True),
    rope_theta=10_000.0,
    mlp_act="silu",
)
