"""falcon-mamba-7b — attention-free mamba1 SSM.

[arXiv:2410.05355; unverified] 64L d_model=4096 d_ff=0 vocab=65024,
ssm_state=16, conv 4, expand 2 (d_inner 8192), dt_rank 256.
"""
from repro.configs.base import ArchConfig, SSMConfig

CONFIG = ArchConfig(
    name="falcon-mamba-7b",
    family="ssm",
    n_layers=64,
    d_model=4096,
    n_heads=1,
    n_kv_heads=1,
    head_dim=64,
    d_ff=0,
    vocab_size=65_024,
    layer_pattern=("mamba",),
    ssm=SSMConfig(d_state=16, d_conv=4, expand=2),
    mlp_act="silu",
)
