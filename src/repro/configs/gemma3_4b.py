"""gemma3-4b — 5:1 local:global attention, 128k context, qk-norm.

[hf:google/gemma-3-1b-pt; unverified] 34L d_model=2560 8H (GQA kv=4)
d_ff=10240 vocab=262144, window 1024, local rope theta 10k / global 1M.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="gemma3-4b",
    family="dense",
    n_layers=34,
    d_model=2560,
    n_heads=8,
    n_kv_heads=4,
    head_dim=256,
    d_ff=10240,
    vocab_size=262_144,
    layer_pattern=("local", "local", "local", "local", "local", "global"),
    attn_window=1024,
    qk_norm=True,
    rope_theta=1_000_000.0,
    tie_embeddings=True,
    scale_embeddings=True,
    post_norms=True,
    mlp_act="gelu",
)
