"""Architecture / run configuration system.

Every assigned architecture is a frozen ``ArchConfig``; input shapes are
``ShapeConfig``s. ``input_specs()`` produces ShapeDtypeStruct stand-ins for the
multi-pod dry-run (no allocation). Reduced smoke variants are derived with
``cfg.smoke()`` so smoke tests always exercise the same layer kinds / pattern
as the full config.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Optional

import jax
import jax.numpy as jnp

# ---------------------------------------------------------------------------
# Sub-configs
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_expert: int                 # expert hidden (ffn) width
    capacity_factor: float = 1.25
    dense_residual: bool = False  # arctic: dense MLP in parallel with MoE
    shared_expert: bool = False   # llama4: always-on shared expert
    router_dtype: str = "float32"


@dataclasses.dataclass(frozen=True)
class SSMConfig:
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2
    dt_rank: int = 0              # 0 -> ceil(d_model / 16)

    def resolved_dt_rank(self, d_model: int) -> int:
        return self.dt_rank or -(-d_model // 16)


@dataclasses.dataclass(frozen=True)
class RGLRUConfig:
    d_rnn: int = 0                # 0 -> d_model
    d_conv: int = 4
    block_width: int = 0          # diagonal-block input gates; 0 -> d_rnn


# ---------------------------------------------------------------------------
# ArchConfig
# ---------------------------------------------------------------------------

# Block kinds understood by models/transformer.py
BLOCK_KINDS = ("dense", "local", "global", "moe", "mamba", "rglru")


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                   # dense | moe | ssm | hybrid | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int
    layer_pattern: tuple[str, ...] = ("dense",)

    # attention details
    attn_window: int = 0          # local-attention window (0 = no local layers)
    pad_heads_to: int = 0         # inert zero-init q heads so heads % TP == 0
                                  # (kills GSPMD mid-head score all-reduces;
                                  #  must keep pad_heads_to % n_kv_heads == 0)
    qkv_bias: bool = False
    attn_softcap: float = 0.0     # gemma2 attention logit soft-capping
    logit_softcap: float = 0.0    # gemma2 final-logit soft-capping
    qk_norm: bool = False         # gemma3 rms-norm on q/k
    query_scale: float = 0.0      # 0 -> 1/sqrt(head_dim)
    rope_theta: float = 10_000.0

    # MLP
    mlp_act: str = "silu"         # silu (swiglu) | gelu (geglu) | relu (plain)
    mlp_glu: bool = True

    # family extensions
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    rglru: Optional[RGLRUConfig] = None

    # embedding / head
    tie_embeddings: bool = False
    scale_embeddings: bool = False   # gemma family: * sqrt(d_model)
    norm_eps: float = 1e-6
    post_norms: bool = False         # gemma2/3: post-attn + post-ffn norms

    # modality frontend (stub; see DESIGN.md)
    frontend: str = ""               # "" | "audio_frames" | "vision_patches"
    n_prefix: int = 0                # prefix embeddings prepended (paligemma patches)
    prefix_bidirectional: bool = False

    # numerics
    param_dtype: str = "float32"
    compute_dtype: str = "bfloat16"

    # training-scale controls
    remat: str = "full"              # none | full | dots
    scan_layers: bool = True
    attn_chunk: int = 1024           # chunked-flash query/kv chunk for long seqs

    # ---------------------------------------------------------------
    def __post_init__(self):
        assert all(k in BLOCK_KINDS for k in self.layer_pattern), self.layer_pattern
        if any(k == "moe" for k in self.layer_pattern):
            assert self.moe is not None
        if any(k == "mamba" for k in self.layer_pattern):
            assert self.ssm is not None
        if any(k == "rglru" for k in self.layer_pattern):
            assert self.rglru is not None
        if any(k == "local" for k in self.layer_pattern):
            assert self.attn_window > 0

    # -- derived -----------------------------------------------------
    @property
    def layer_kinds(self) -> tuple[str, ...]:
        """Kind of every layer, 0..n_layers-1 (pattern tiled + truncated)."""
        p = self.layer_pattern
        reps = -(-self.n_layers // len(p))
        return (p * reps)[: self.n_layers]

    @property
    def d_inner(self) -> int:
        return self.ssm.expand * self.d_model if self.ssm else 0

    @property
    def d_rnn(self) -> int:
        if not self.rglru:
            return 0
        return self.rglru.d_rnn or self.d_model

    @property
    def sub_quadratic(self) -> bool:
        """True when the pattern contains a bounded-cost mixer (local
        window / SSM / RG-LRU): such hybrids run long_500k with the few
        global layers' KV caches sequence-sharded over `data` (SP decode).
        Pure full-attention archs (incl. full-attn MoE) skip it
        (DESIGN.md §5)."""
        return any(k in ("local", "mamba", "rglru")
                   for k in self.layer_pattern)

    def param_count(self) -> int:
        """Analytic parameter count (embedding + blocks), for 6ND roofline."""
        return _param_count(self, active_only=False)

    def active_param_count(self) -> int:
        return _param_count(self, active_only=True)

    # -- reduced variant ----------------------------------------------
    def smoke(self) -> "ArchConfig":
        """Tiny same-family config for CPU smoke tests."""
        period = len(self.layer_pattern)
        n_layers = period + 1 if self.n_layers > period else period  # period + remainder
        kw: dict[str, Any] = dict(
            n_layers=n_layers,
            d_model=64,
            n_heads=4,
            n_kv_heads=min(self.n_kv_heads, 2) if self.n_kv_heads < self.n_heads else 4,
            head_dim=16,
            d_ff=128,
            vocab_size=256,
            attn_window=min(self.attn_window, 32) if self.attn_window else 0,
            attn_chunk=32,
            n_prefix=min(self.n_prefix, 4),
            remat="none",
        )
        if self.moe:
            kw["moe"] = dataclasses.replace(self.moe, n_experts=8, d_expert=96)
        if self.ssm:
            kw["ssm"] = dataclasses.replace(self.ssm, d_state=8)
        if self.rglru:
            kw["rglru"] = dataclasses.replace(self.rglru, d_rnn=64, block_width=32)
        return dataclasses.replace(self, name=self.name + "-smoke", **kw)


def _param_count(cfg: ArchConfig, active_only: bool) -> int:
    M, V = cfg.d_model, cfg.vocab_size
    n = V * M  # embedding
    if not cfg.tie_embeddings:
        n += V * M
    for kind in cfg.layer_kinds:
        if kind in ("dense", "local", "global", "moe"):
            # attention
            n += M * cfg.n_heads * cfg.head_dim * 2          # q, o
            n += M * cfg.n_kv_heads * cfg.head_dim * 2       # k, v
        if kind in ("dense", "local", "global"):
            n += M * cfg.d_ff * (3 if cfg.mlp_glu else 2)
        elif kind == "moe":
            m = cfg.moe
            e = m.top_k if active_only else m.n_experts
            n += e * M * m.d_expert * (3 if cfg.mlp_glu else 2)
            if m.shared_expert:
                n += M * m.d_expert * (3 if cfg.mlp_glu else 2)
            if m.dense_residual:
                n += M * cfg.d_ff * (3 if cfg.mlp_glu else 2)
            n += M * m.n_experts                              # router
        elif kind == "mamba":
            s = cfg.ssm
            di, dr = cfg.d_inner, s.resolved_dt_rank(M)
            n += M * 2 * di            # in_proj
            n += di * s.d_conv         # conv
            n += di * (dr + 2 * s.d_state)  # x_proj
            n += dr * di               # dt_proj
            n += di * s.d_state + 2 * di    # A_log, D, dt bias-ish
            n += di * M                # out_proj
        elif kind == "rglru":
            dr = cfg.d_rnn
            n += M * dr * 2            # x, y branches in
            n += dr * cfg.rglru.d_conv
            n += 2 * dr * (cfg.rglru.block_width or dr)  # input/recurrent gates
            n += dr * M                # out
            n += M * cfg.d_ff * (3 if cfg.mlp_glu else 2)  # block MLP
        n += 2 * M                     # pre-norms (approx; post_norms ignored)
    return n


# ---------------------------------------------------------------------------
# Shapes
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}


def shape_applicable(cfg: ArchConfig, shape: ShapeConfig) -> tuple[bool, str]:
    """(applicable, reason-if-not). DESIGN.md §5."""
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return False, "pure full-attention arch: 500k context skipped (DESIGN.md §5)"
    return True, ""


def input_specs(cfg: ArchConfig, shape: ShapeConfig) -> dict[str, jax.ShapeDtypeStruct]:
    """ShapeDtypeStruct stand-ins for every model input of this (arch, shape).

    No device allocation — used by the dry-run and by jax.eval_shape.
    """
    B, S = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    specs: dict[str, jax.ShapeDtypeStruct] = {}
    if shape.kind == "train":
        specs["tokens"] = jax.ShapeDtypeStruct((B, S), i32)
        specs["targets"] = jax.ShapeDtypeStruct((B, S), i32)
        specs["loss_mask"] = jax.ShapeDtypeStruct((B, S), jnp.float32)
    elif shape.kind == "prefill":
        specs["tokens"] = jax.ShapeDtypeStruct((B, S), i32)
    else:  # decode: one new token with a KV cache of seq_len (built separately)
        specs["tokens"] = jax.ShapeDtypeStruct((B, 1), i32)
        specs["pos"] = jax.ShapeDtypeStruct((B,), i32)
    if cfg.n_prefix:
        dt = jnp.dtype(cfg.compute_dtype)
        specs["prefix_embed"] = jax.ShapeDtypeStruct(
            (B, cfg.n_prefix, cfg.d_model), dt
        )
    return specs


def flops_per_step(cfg: ArchConfig, shape: ShapeConfig) -> float:
    """MODEL_FLOPS = 6·N·D (dense) / 6·N_active·D (MoE); D = tokens this step.

    Train counts fwd+bwd (6ND); prefill/decode are forward-only (2ND).
    """
    n = cfg.active_param_count() if cfg.moe else cfg.param_count()
    if shape.kind == "train":
        d = shape.global_batch * shape.seq_len
        return 6.0 * n * d
    if shape.kind == "prefill":
        return 2.0 * n * shape.global_batch * shape.seq_len
    return 2.0 * n * shape.global_batch  # decode: one token per sequence
