"""paligemma-3b — SigLIP vision frontend (STUB) + gemma-2b text backbone.

[arXiv:2407.07726; hf] 18L d_model=2048 8H (MQA kv=1) d_ff=16384 vocab=257216.
input_specs() supplies 256 precomputed patch embeddings prepended to the text;
prefix attends bidirectionally (prefix-LM), suffix is causal.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="paligemma-3b",
    family="vlm",
    n_layers=18,
    d_model=2048,
    n_heads=8,
    n_kv_heads=1,
    head_dim=256,
    d_ff=16384,
    vocab_size=257_216,
    layer_pattern=("dense",),
    frontend="vision_patches",
    n_prefix=256,
    prefix_bidirectional=True,
    tie_embeddings=True,
    scale_embeddings=True,
    mlp_act="gelu",
)
