"""musicgen-medium — decoder-only transformer over EnCodec tokens.

[arXiv:2306.05284; hf] 48L d_model=1536 24H (MHA, kv=24) d_ff=6144 vocab=2048.
The EnCodec audio frontend is a STUB (input is the token stream / precomputed
frame embeddings per DESIGN.md); plain (non-GLU) GELU FFN per the released
t5-style decoder.
"""
from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="musicgen-medium",
    family="audio",
    n_layers=48,
    d_model=1536,
    n_heads=24,
    n_kv_heads=24,
    head_dim=64,
    d_ff=6144,
    vocab_size=2048,
    layer_pattern=("dense",),
    frontend="audio_frames",
    mlp_act="gelu",
    mlp_glu=False,
)
