"""Architecture registry: ``get_config("<arch-id>")`` / ``--arch <id>``."""
from __future__ import annotations

import importlib

from repro.configs.base import (  # noqa: F401
    ArchConfig,
    MoEConfig,
    RGLRUConfig,
    SSMConfig,
    ShapeConfig,
    SHAPES,
    flops_per_step,
    input_specs,
    shape_applicable,
)

_MODULES = {
    "llama4-maverick-400b-a17b": "llama4_maverick_400b_a17b",
    "arctic-480b": "arctic_480b",
    "recurrentgemma-9b": "recurrentgemma_9b",
    "musicgen-medium": "musicgen_medium",
    "paligemma-3b": "paligemma_3b",
    "qwen2-72b": "qwen2_72b",
    "granite-34b": "granite_34b",
    "gemma2-27b": "gemma2_27b",
    "gemma3-4b": "gemma3_4b",
    "falcon-mamba-7b": "falcon_mamba_7b",
}

ARCH_IDS = tuple(_MODULES)


def get_config(arch: str) -> ArchConfig:
    arch = arch.replace("_", "-")
    if arch.endswith("-smoke"):
        return get_config(arch[: -len("-smoke")]).smoke()
    if arch not in _MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(_MODULES)}")
    mod = importlib.import_module(f"repro.configs.{_MODULES[arch]}")
    return mod.CONFIG


def all_configs() -> dict[str, ArchConfig]:
    return {a: get_config(a) for a in ARCH_IDS}
