"""Pluggable analyzers — string-keyed registry, same pattern as
``repro.transport``'s ``@register_transport``.

An :class:`Analyzer` consumes :class:`~repro.analysis.session.QueryResult`
objects (or raw arrays) via ``update`` and emits a typed
:class:`Summary`. New analysis workloads register a class and are
immediately reachable from ``launch/serve.py --analyzer <name>`` and any
``AnalysisSession`` consumer — no wire-layer changes.

Built-ins:
  * ``running_stats``  — streaming mean/min/max/std/count;
  * ``histogram``      — streaming histogram (range frozen by first batch);
  * ``window_reduce``  — reduction over the last W slices of the ``step``
                         dimension (per-step scalar series kept).
"""
from __future__ import annotations

import abc
import collections
import dataclasses
from typing import Any, Callable, Optional

import numpy as np


@dataclasses.dataclass(frozen=True)
class Summary:
    """Typed analyzer output: who produced it, on how much, and what."""

    analyzer: str
    n_updates: int
    payload: dict[str, Any]

    def __getitem__(self, key: str):
        return self.payload[key]


class Analyzer(abc.ABC):
    """Streaming analysis over query results: ``update`` per result,
    ``summary`` at any point (analyzers are cheap to summarize mid-stream,
    matching the query-while-running model)."""

    name: str = "abstract"

    def __init__(self, **kw):
        if kw:
            raise TypeError(f"analyzer {self.name!r} takes no options {kw}")
        self.n_updates = 0

    def update(self, result) -> None:
        """Consume one QueryResult (or anything array-like)."""
        arr = np.asarray(getattr(result, "array", result))
        self.n_updates += 1
        self._consume(arr)

    @abc.abstractmethod
    def _consume(self, arr: np.ndarray) -> None:
        ...

    @abc.abstractmethod
    def summary(self) -> Summary:
        ...


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------


class UnknownAnalyzerError(KeyError):
    pass


_REGISTRY: dict[str, type] = {}


def register_analyzer(name: str) -> Callable[[type], type]:
    """Class decorator: ``@register_analyzer("running_stats")``."""

    def deco(cls: type) -> type:
        if name in _REGISTRY and _REGISTRY[name] is not cls:
            raise ValueError(f"analyzer {name!r} already registered "
                             f"({_REGISTRY[name].__name__})")
        cls.name = name
        _REGISTRY[name] = cls
        return cls

    return deco


def available() -> tuple[str, ...]:
    """Registered analyzer names, sorted."""
    return tuple(sorted(_REGISTRY))


def get(name: str) -> type:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise UnknownAnalyzerError(
            f"unknown analyzer {name!r}; available: {', '.join(available())}"
        ) from None


def create(name: str, **kw) -> Analyzer:
    """Instantiate a registered analyzer with its options."""
    return get(name)(**kw)


# ---------------------------------------------------------------------------
# built-ins
# ---------------------------------------------------------------------------


@register_analyzer("running_stats")
class RunningStats(Analyzer):
    """Streaming count/mean/min/max/std over every value seen."""

    def __init__(self):
        super().__init__()
        self._n = 0
        self._sum = 0.0
        self._sumsq = 0.0
        self._min = np.inf
        self._max = -np.inf

    def _consume(self, arr: np.ndarray) -> None:
        if arr.size == 0:
            return
        x = arr.astype(np.float64, copy=False)
        self._n += x.size
        self._sum += float(x.sum())
        self._sumsq += float((x * x).sum())
        self._min = min(self._min, float(x.min()))
        self._max = max(self._max, float(x.max()))

    def summary(self) -> Summary:
        n = max(self._n, 1)
        mean = self._sum / n
        var = max(self._sumsq / n - mean * mean, 0.0)
        return Summary(self.name, self.n_updates, {
            "count": self._n, "mean": mean, "std": var ** 0.5,
            "min": self._min if self._n else 0.0,
            "max": self._max if self._n else 0.0,
        })


@register_analyzer("histogram")
class Histogram(Analyzer):
    """Streaming histogram. The bin range is fixed up front (``lo``/``hi``)
    or frozen by the first non-empty batch; later out-of-range values land
    in the edge bins (clipped), so counts always sum to values seen."""

    def __init__(self, bins: int = 16, lo: Optional[float] = None,
                 hi: Optional[float] = None):
        super().__init__()
        if bins < 1:
            raise ValueError("bins must be >= 1")
        if (lo is None) != (hi is None):
            raise ValueError("histogram range needs both lo and hi "
                             "(or neither, to freeze on first batch)")
        if lo is not None and not hi > lo:
            raise ValueError(f"histogram range empty: [{lo}, {hi})")
        self.bins = bins
        self._lo, self._hi = lo, hi
        self._counts = np.zeros(bins, np.int64)

    def _consume(self, arr: np.ndarray) -> None:
        if arr.size == 0:
            return
        x = arr.astype(np.float64, copy=False).reshape(-1)
        if self._lo is None:
            self._lo = float(x.min())
            self._hi = float(x.max())
            if self._hi == self._lo:
                self._hi = self._lo + 1.0
        idx = (x - self._lo) / (self._hi - self._lo) * self.bins
        idx = np.clip(idx.astype(np.int64), 0, self.bins - 1)
        self._counts += np.bincount(idx, minlength=self.bins)

    def summary(self) -> Summary:
        lo = 0.0 if self._lo is None else self._lo
        hi = 1.0 if self._hi is None else self._hi
        edges = np.linspace(lo, hi, self.bins + 1)
        return Summary(self.name, self.n_updates, {
            "counts": self._counts.tolist(), "edges": edges.tolist(),
            "total": int(self._counts.sum()),
        })


@register_analyzer("window_reduce")
class WindowReduce(Analyzer):
    """Reduction over the last ``window`` updates of a per-step series.

    Each ``update`` is one step's worth of data (e.g. the subtar a
    ``watch()`` event announced); it is collapsed to a scalar with
    ``step_op`` and the trailing ``window`` scalars are reduced with
    ``op`` — a running "energy over the last W steps" style diagnostic.
    """

    def __init__(self, window: int = 8, op: str = "mean",
                 step_op: str = "sum"):
        super().__init__()
        if window < 1:
            raise ValueError("window must be >= 1")
        for o in (op, step_op):
            if o not in ("sum", "mean", "max", "min", "std"):
                raise ValueError(f"unknown reduction {o!r}")
        self.window, self.op, self.step_op = window, op, step_op
        self._series: collections.deque = collections.deque(maxlen=window)

    def _consume(self, arr: np.ndarray) -> None:
        if arr.size == 0:
            return
        self._series.append(float(
            getattr(np, self.step_op)(arr.astype(np.float64, copy=False))))

    def summary(self) -> Summary:
        series = list(self._series)
        value = float(getattr(np, self.op)(series)) if series else 0.0
        return Summary(self.name, self.n_updates, {
            "value": value, "series": series, "window": self.window,
            "op": self.op, "step_op": self.step_op,
        })
