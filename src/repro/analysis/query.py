"""Typed query layer — the ONLY place SAVIME mini-language text is built.

Every statement the repo sends to SAVIME is a frozen dataclass with a
``compile()`` method; callers construct statements (or use the fluent
builder below) and hand them to :class:`~repro.analysis.AnalysisSession`
or any ``run_savime``-bearing transport. Raw query strings are wire
plumbing, not an API: grep for ``compile`` — this module is the compiler.

    from repro.analysis import tar
    stmt = tar("velocity").attr("v").range((0, 0, 0), (10, 10, 10)).mean()
    stmt.compile()   # -> 'aggregate(velocity, v, mean, "0,0,0", "10,10,10")'

DDL statements take the TARS schema types (``repro.core.tars.Dimension``
/ ``Attribute``) so the client-side description and the engine-side
catalogue cannot drift apart. They are duck-typed here (``name`` /
``lower`` / ``upper`` / ``offset`` / ``stride``, ``name`` / ``dtype``)
rather than imported: this module must stay a leaf so every layer —
including ``repro.core`` itself — can compile through it.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Optional, Sequence

import numpy as np

AGG_OPS = ("sum", "mean", "max", "min", "std", "count")


def _point(p: Sequence[int]) -> str:
    return ",".join(str(int(x)) for x in p)


def _dim_spec(d: Any) -> str:
    """``d`` is a ``repro.core.tars.Dimension`` (duck-typed)."""
    spec = f"{d.name}:{d.lower}:{d.upper}"
    if d.offset != 0.0 or d.stride != 1.0:
        spec += f":{d.offset}:{d.stride}"
    return spec


def _attr_spec(a: Any) -> str:
    """``a`` is a ``repro.core.tars.Attribute`` (duck-typed)."""
    return f"{a.name}:{a.dtype}"


def _check_box(lo, hi) -> None:
    if (lo is None) != (hi is None):
        raise ValueError("range needs both lo and hi (or neither)")
    if lo is not None and len(lo) != len(hi):
        raise ValueError(f"range rank mismatch: {lo} vs {hi}")


class Statement:
    """Base for all typed statements. ``kind`` feeds per-query stats;
    ``idempotent`` tells the session whether a lost-reply retry is safe
    (re-running ``create_tar``/``load_subtar`` after the server already
    applied it fails or double-loads)."""

    idempotent = False

    @property
    def kind(self) -> str:
        return type(self).__name__.lower()

    def compile(self) -> str:  # pragma: no cover - subclasses override
        raise NotImplementedError

    def __str__(self) -> str:
        return self.compile()


# ---------------------------------------------------------------------------
# DDL / ingestion statements
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class CreateTar(Statement):
    """``create_tar`` — declare a TAR from TARS schema objects."""

    tar: str
    dims: tuple[Any, ...]       # repro.core.tars.Dimension objects
    attrs: tuple[Any, ...]      # repro.core.tars.Attribute objects

    def compile(self) -> str:
        dims = ", ".join(_dim_spec(d) for d in self.dims)
        attrs = ", ".join(_attr_spec(a) for a in self.attrs)
        return f'create_tar({self.tar}, "{dims}", "{attrs}")'


@dataclasses.dataclass(frozen=True)
class LoadSubtar(Statement):
    """``load_subtar`` — attach an ingested dataset as a subtar payload."""

    tar: str
    dataset: str
    origin: tuple[int, ...]
    shape: tuple[int, ...]
    attr: str

    def __post_init__(self):
        if len(self.origin) != len(self.shape):
            raise ValueError(f"origin/shape rank mismatch: "
                             f"{self.origin} vs {self.shape}")

    def compile(self) -> str:
        return (f'load_subtar({self.tar}, {self.dataset}, '
                f'"{_point(self.origin)}", "{_point(self.shape)}", '
                f'{self.attr})')


@dataclasses.dataclass(frozen=True)
class DropTar(Statement):
    idempotent = True               # dropping a dropped tar is a no-op

    tar: str

    def compile(self) -> str:
        return f"drop_tar({self.tar})"


# ---------------------------------------------------------------------------
# analytical statements
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Select(Statement):
    """Dimension/range filter — the paper's §6 "filtering stored data by
    dimensions and by range"."""

    idempotent = True

    tar: str
    attr: str
    lo: Optional[tuple[int, ...]] = None
    hi: Optional[tuple[int, ...]] = None

    def __post_init__(self):
        _check_box(self.lo, self.hi)

    def compile(self) -> str:
        if self.lo is not None:
            return (f'select({self.tar}, {self.attr}, '
                    f'"{_point(self.lo)}", "{_point(self.hi)}")')
        return f"select({self.tar}, {self.attr})"


@dataclasses.dataclass(frozen=True)
class Aggregate(Statement):
    idempotent = True

    tar: str
    attr: str
    op: str
    lo: Optional[tuple[int, ...]] = None
    hi: Optional[tuple[int, ...]] = None

    def __post_init__(self):
        if self.op not in AGG_OPS:
            raise ValueError(f"unknown aggregate op {self.op!r}; "
                             f"one of {', '.join(AGG_OPS)}")
        _check_box(self.lo, self.hi)

    def compile(self) -> str:
        if self.lo is not None:
            return (f'aggregate({self.tar}, {self.attr}, {self.op}, '
                    f'"{_point(self.lo)}", "{_point(self.hi)}")')
        return f"aggregate({self.tar}, {self.attr}, {self.op})"


@dataclasses.dataclass(frozen=True)
class Window(Statement):
    """Windowed reduction over one dimension (by default the leading
    ``step`` dimension every sink-created TAR carries).

    The mini-language has no window operator, so this compiles to the
    underlying ``select`` and reduces client-side in ``finalize``: the
    trailing ``size`` slices along ``dim`` are reduced with ``op``,
    collapsing that axis (e.g. the mean field over the last 8 steps).
    """

    idempotent = True

    tar: str
    attr: str
    op: str = "mean"
    dim: int = 0
    size: int = 8
    lo: Optional[tuple[int, ...]] = None
    hi: Optional[tuple[int, ...]] = None

    def __post_init__(self):
        if self.op not in ("sum", "mean", "max", "min", "std"):
            raise ValueError(f"unknown window op {self.op!r}")
        if self.size < 1:
            raise ValueError("window size must be >= 1")
        _check_box(self.lo, self.hi)

    def compile(self) -> str:
        return Select(self.tar, self.attr, self.lo, self.hi).compile()

    def finalize(self, raw):
        arr = np.asarray(raw)
        if arr.ndim == 0 or arr.size == 0:
            return arr
        win = np.moveaxis(arr, self.dim, 0)[-self.size:]
        return getattr(np, self.op)(win, axis=0)


# ---------------------------------------------------------------------------
# fluent builder
# ---------------------------------------------------------------------------


class QueryBuilder:
    """Fluent construction of analytical statements:

        tar("velocity").attr("v").range((0,0,0), (10,10,10)).mean()

    Terminal methods (``select`` / ``mean`` / ... / ``window``) return the
    frozen statement dataclass; the builder itself is cheap and single-use.
    """

    def __init__(self, tar_name: str):
        self._tar = tar_name
        self._attr: Optional[str] = None
        self._lo: Optional[tuple[int, ...]] = None
        self._hi: Optional[tuple[int, ...]] = None

    def attr(self, name: str) -> "QueryBuilder":
        self._attr = name
        return self

    def range(self, lo: Sequence[int], hi: Sequence[int]) -> "QueryBuilder":
        _check_box(tuple(lo), tuple(hi))
        self._lo, self._hi = tuple(int(x) for x in lo), \
            tuple(int(x) for x in hi)
        return self

    def _need_attr(self) -> str:
        if self._attr is None:
            raise ValueError(f"query on tar {self._tar!r} needs .attr(name)")
        return self._attr

    # -- terminals ------------------------------------------------------
    def select(self) -> Select:
        return Select(self._tar, self._need_attr(), self._lo, self._hi)

    def aggregate(self, op: str) -> Aggregate:
        return Aggregate(self._tar, self._need_attr(), op, self._lo, self._hi)

    def sum(self) -> Aggregate:
        return self.aggregate("sum")

    def mean(self) -> Aggregate:
        return self.aggregate("mean")

    def max(self) -> Aggregate:
        return self.aggregate("max")

    def min(self) -> Aggregate:
        return self.aggregate("min")

    def std(self) -> Aggregate:
        return self.aggregate("std")

    def count(self) -> Aggregate:
        return self.aggregate("count")

    def window(self, size: int = 8, op: str = "mean", dim: int = 0) -> Window:
        return Window(self._tar, self._need_attr(), op, dim, size,
                      self._lo, self._hi)


def tar(name: str) -> QueryBuilder:
    """Entry point of the fluent builder (mirrors SQL's FROM)."""
    return QueryBuilder(name)
