# The analytical half of the paper, as one typed surface (§6: "filtering
# stored data by dimensions and by range" while the simulation runs):
#   * repro.analysis.query     — typed statements + fluent builder; the
#     ONLY place SAVIME mini-language text is assembled;
#   * AnalysisSession          — reader-side twin of TransferSession:
#     owns the connection, typed QueryResults, retry/reconnect, stats,
#     and watch() live subscriptions (subscribe/notify wire ops);
#   * repro.analysis.analyzers — @register_analyzer registry of streaming
#     analyses consuming QueryResults and emitting typed Summaries.
# See DESIGN.md §8 for the API and the migration table from raw query
# strings.
from repro.analysis.query import (  # noqa: F401
    AGG_OPS, Aggregate, CreateTar, DropTar, LoadSubtar, QueryBuilder,
    Select, Statement, Window, tar,
)
from repro.analysis.session import (  # noqa: F401
    AnalysisSession, AnalysisStats, QueryResult, SubscriptionClosed,
    SubtarEvent, Subscription,
)
from repro.analysis import analyzers  # noqa: F401
from repro.analysis.analyzers import (  # noqa: F401
    Analyzer, Summary, UnknownAnalyzerError, register_analyzer,
)
