"""AnalysisSession — the one user-facing way to read from the analytical
side (mirror of :class:`repro.transport.TransferSession` for egress).

    from repro.analysis import AnalysisSession, tar

    with AnalysisSession(savime.addr) as an:
        res = an.execute(tar("velocity").attr("v").range(lo, hi).mean())
        print(res.value, res.elapsed_s)
        with an.watch("velocity") as sub:      # live subscription (§6:
            for event in sub:                  # query while running)
                ...

The session owns the SAVIME connection (or rides any ``run_savime``-
bearing transport via ``via=`` — the compute-node mode where the
analytical network is only reachable through staging), executes typed
statements from :mod:`repro.analysis.query`, returns
:class:`QueryResult` (value + dtype/shape + timing), retries and
reconnects on connection loss, and keeps per-kind query stats.
"""
from __future__ import annotations

import dataclasses
import select as _select
import time
from typing import Any, Iterator, Optional

import numpy as np

from repro.core import wire
from repro.core.retry import RetryPolicy
from repro.core.savime import SavimeClient, SavimeError
from repro.analysis.query import Statement


class SubscriptionClosed(ConnectionError):
    """The subscription's push connection is gone (server died or the
    subscription was closed) — distinct from ``poll()`` returning ``None``,
    which only means nothing arrived within the timeout."""


# ---------------------------------------------------------------------------
# typed results / stats / events
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class QueryResult:
    """One executed statement: the compiled text, the typed value, and
    where the time went."""

    query: str
    kind: str
    value: Any
    dtype: Optional[str]
    shape: Optional[tuple[int, ...]]
    elapsed_s: float
    attempts: int = 1

    @property
    def array(self) -> np.ndarray:
        """The value as a numpy array (scalars become 0-d)."""
        return np.asarray(self.value)

    @property
    def scalar(self) -> float:
        return float(self.array)


@dataclasses.dataclass
class AnalysisStats:
    """Per-session query accounting (reader-side twin of TransferStats)."""

    endpoint: str = ""
    n_queries: int = 0
    n_retries: int = 0
    n_reconnects: int = 0
    query_s: float = 0.0
    result_bytes: int = 0
    by_kind: dict = dataclasses.field(default_factory=dict)

    @property
    def mean_query_s(self) -> float:
        return self.query_s / max(self.n_queries, 1)

    def as_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d["mean_query_s"] = self.mean_query_s
        return d

    @classmethod
    def merge(cls, stats) -> "AnalysisStats":
        """Combine per-session accounting into one reader-fleet view.

        Everything here is a flow (counts, summed query seconds, result
        bytes) so every field adds — unlike
        :meth:`TransferStats.merge`, no field is a high-water mark.
        ``by_kind`` histograms add key-wise; endpoints join with ``+``.
        """
        stats = list(stats)
        if not stats:
            return cls(endpoint="merged")
        endpoints = [s.endpoint for s in stats if s.endpoint]
        out = cls(endpoint="+".join(dict.fromkeys(endpoints)) or "merged")
        for s in stats:
            out.n_queries += s.n_queries
            out.n_retries += s.n_retries
            out.n_reconnects += s.n_reconnects
            out.query_s += s.query_s
            out.result_bytes += s.result_bytes
            for k, v in s.by_kind.items():
                out.by_kind[k] = out.by_kind.get(k, 0) + v
        return out


@dataclasses.dataclass(frozen=True)
class SubtarEvent:
    """One ``notify`` push: a subtar landed in ``tar`` while we watched."""

    tar: str
    origin: tuple[int, ...]
    shape: tuple[int, ...]
    attr: str
    seq: int

    @property
    def hi(self) -> tuple[int, ...]:
        """Inclusive upper corner — feeds straight into ``.range()``."""
        return tuple(o + s - 1 for o, s in zip(self.origin, self.shape))


# ---------------------------------------------------------------------------
# live subscription
# ---------------------------------------------------------------------------


class Subscription:
    """Iterator over subtar-arrival events for one TAR (``""`` = all,
    trailing ``*`` = prefix match).

    Registration is eager: by the time the constructor returns, the
    server acknowledged the subscription, so every subtar loaded after
    that point is delivered — no subscribe/ingest race. Iteration ends
    after ``max_events`` events or a ``timeout``-second wait with nothing
    arriving; ``poll`` never ends the iteration and is the right call in
    a supervision loop that owns its own stop condition.
    """

    def __init__(self, addr: str, tar: str = "", *,
                 timeout: Optional[float] = None,
                 max_events: Optional[int] = None):
        self.tar = tar
        self.timeout = timeout
        self.max_events = max_events
        self.n_events = 0
        self._closed = False
        self._sock = wire.connect(addr)
        header, _ = wire.request(self._sock, {"op": "subscribe", "tar": tar})
        if not header.get("ok"):
            self._sock.close()
            raise SavimeError(header.get("error", "subscribe failed"))
        self.start_seq = int(header.get("seq", 0))

    @property
    def closed(self) -> bool:
        """True once the push connection is gone (server side or ours)."""
        return self._closed

    def poll(self, timeout: Optional[float] = None) -> Optional[SubtarEvent]:
        """Next event, or ``None`` after ``timeout`` seconds of nothing
        arriving. A dead server is not a timeout: it raises
        :class:`SubscriptionClosed` (and sets :attr:`closed`), so a
        supervision loop can tell "quiet" from "gone"."""
        if self._closed:
            raise SubscriptionClosed(
                f"subscription to {self.tar!r} is closed")
        ready, _, _ = _select.select([self._sock], [], [], timeout)
        if not ready:
            return None
        try:
            header, _ = wire.recv_frame(self._sock)
        except (ConnectionError, OSError) as e:
            self.close()
            raise SubscriptionClosed(
                f"subscription to {self.tar!r}: server gone ({e})") from e
        if header.get("op") != "notify":
            return None
        self.n_events += 1
        return SubtarEvent(tar=header["tar"],
                           origin=tuple(header["origin"]),
                           shape=tuple(header["shape"]),
                           attr=header.get("attr", ""),
                           seq=int(header.get("seq", 0)))

    def __iter__(self) -> Iterator[SubtarEvent]:
        return self

    def __next__(self) -> SubtarEvent:
        if self.max_events is not None and self.n_events >= self.max_events:
            raise StopIteration
        try:
            ev = self.poll(self.timeout)
        except SubscriptionClosed:
            raise StopIteration from None
        if ev is None:
            raise StopIteration
        return ev

    def close(self) -> None:
        if self._closed:
            return
        self._closed = True
        try:
            self._sock.close()
        except OSError:
            pass

    def __enter__(self) -> "Subscription":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


# ---------------------------------------------------------------------------
# session
# ---------------------------------------------------------------------------


class AnalysisSession:
    """Context manager owning one analytical connection.

    Exactly one of:
      * ``addr``  — connect straight to a SAVIME server (analytical
        network; enables ``watch``);
      * ``via``   — ride anything with ``run_savime`` (a
        :class:`~repro.transport.TransferSession` or Transport): the
        compute-node mode, where SAVIME is only reachable through the
        staging proxy. ``via`` objects own their connection, so retry /
        reconnect stays on the direct path only.
    """

    def __init__(self, addr: Optional[str] = None, *,
                 via: Optional[Any] = None, retries: int = 2,
                 retry_backoff_s: float = 0.05,
                 deadline_s: Optional[float] = None,
                 label: Optional[str] = None):
        if (addr is None) == (via is None):
            raise ValueError(
                "AnalysisSession needs exactly one of addr= or via=")
        self.addr = addr
        self._via = via
        self.retries = retries
        self.retry_backoff_s = retry_backoff_s
        # shared retry engine (DESIGN.md §15): exponential backoff with
        # full jitter, capped by an optional wall-clock deadline; exhausting
        # it raises the typed RetryExhausted instead of the last bare error
        self._retry = RetryPolicy(retries=retries, base_s=retry_backoff_s,
                                  deadline_s=deadline_s)
        self.stats = AnalysisStats(
            endpoint=label or addr or f"via:{type(via).__name__}")
        self._cli: Optional[SavimeClient] = None
        self._opened = False
        self._closed = False

    # -- lifecycle ------------------------------------------------------
    def open(self) -> "AnalysisSession":
        if self._opened:
            return self
        if self.addr is not None:
            self._cli = SavimeClient(self.addr)
        self._opened = True
        return self

    def __enter__(self) -> "AnalysisSession":
        return self.open()

    def __exit__(self, *exc) -> None:
        self.close()

    def close(self) -> None:
        self._closed = True
        if self._cli is not None:
            self._cli.close()
            self._cli = None

    # -- execution ------------------------------------------------------
    def execute(self, stmt: "Statement | str") -> QueryResult:
        """Run one typed statement (raw strings are accepted for
        debugging but deprecated — see DESIGN.md §8)."""
        self._check_live()
        q = stmt.compile() if isinstance(stmt, Statement) else str(stmt)
        kind = stmt.kind if isinstance(stmt, Statement) else "raw"
        t0 = time.perf_counter()
        attempts = 0
        retryable = getattr(stmt, "idempotent", False)
        for attempt in self._retry.attempts(f"query {kind}"):
            attempts += 1
            try:
                raw = self._run(q)
                break
            except (ConnectionError, OSError) as e:
                # SavimeError (semantic) propagates immediately; only a
                # lost connection on the session-owned path is retried,
                # and only for idempotent statements — the server may
                # have applied a create/load whose reply was lost
                if self._cli is None or not retryable:
                    raise
                self.stats.n_retries += 1
                attempt.backoff(e)     # jittered sleep or RetryExhausted
                try:
                    self._reconnect()
                except (ConnectionError, OSError):
                    pass   # still down: next attempt backs off again,
                #            so exhaustion surfaces as RetryExhausted
        if hasattr(stmt, "finalize"):
            raw = stmt.finalize(raw)
        elapsed = time.perf_counter() - t0
        if isinstance(raw, np.ndarray):
            dtype, shape = str(raw.dtype), tuple(raw.shape)
            self.stats.result_bytes += raw.nbytes
        else:
            dtype = shape = None
        self.stats.n_queries += 1
        self.stats.query_s += elapsed
        self.stats.by_kind[kind] = self.stats.by_kind.get(kind, 0) + 1
        return QueryResult(query=q, kind=kind, value=raw, dtype=dtype,
                           shape=shape, elapsed_s=elapsed, attempts=attempts)

    def execute_all(self, stmts) -> list[QueryResult]:
        return [self.execute(s) for s in stmts]

    def _run(self, q: str):
        if self._cli is not None:
            return self._cli.run(q)
        return self._via.run_savime(q)

    def _reconnect(self) -> None:
        assert self.addr is not None
        try:
            self._cli.close()
        except (OSError, AttributeError):
            pass
        self._cli = SavimeClient(self.addr)
        self.stats.n_reconnects += 1

    # -- live subscription ---------------------------------------------
    def watch(self, tar: str = "", *, timeout: Optional[float] = None,
              max_events: Optional[int] = None) -> Subscription:
        """Subscribe to subtar arrivals in ``tar`` (the paper's
        query-while-running goal made first-class). Needs a direct SAVIME
        address — the subscription is its own push connection, so queries
        on this session proceed while events stream in."""
        self._check_live()
        if self.addr is None:
            raise RuntimeError(
                "watch() needs a direct SAVIME address; via= sessions sit "
                "behind the staging control proxy, which has no push path")
        return Subscription(self.addr, tar, timeout=timeout,
                            max_events=max_events)

    # -- introspection --------------------------------------------------
    def server_stats(self) -> dict:
        self._check_live()
        if self._cli is not None:
            return self._cli.stats()
        return self._via.server_stats()

    def _check_live(self) -> None:
        if not self._opened:
            raise RuntimeError("AnalysisSession not opened "
                               "(use `with` or .open())")
        if self._closed:
            raise RuntimeError("AnalysisSession already closed")
