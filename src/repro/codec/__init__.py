"""Negotiated egress-path reduction codecs (DESIGN.md section 13).

Selected per session via ``TransportConfig.codec`` / ``decode_at``,
negotiated per connection through the ``hello`` handshake (JSON-fallback
peers silently get ``none``).
"""
from .base import (Codec, CodecError, CodecOrderError, UnknownCodecError,
                   as_bytes_array, available, create, get, np_dtype,
                   register_codec)
from .bytecodecs import DeltaRleCodec, NoneCodec
from .int8block import Int8BlockCodec

__all__ = [
    "Codec", "CodecError", "CodecOrderError", "UnknownCodecError",
    "as_bytes_array", "available", "create", "get", "np_dtype",
    "register_codec", "NoneCodec", "DeltaRleCodec", "Int8BlockCodec",
]
