"""Codec ABC + string-keyed registry for egress-path data reduction.

A codec shrinks dataset bytes *before* they cross the staging hop
(Catalyst-ADIOS2's "reduce at the producer" rule).  Codecs are symmetric:
``encode`` runs client-side (optionally on-device), ``decode`` runs at the
staging server — either at ingest (default, full fidelity to SAVIME) or
lazily at forward/query time (``decode_at="query"``).

The registry mirrors ``transport/base.py`` and ``analysis/analyzers.py``:
string-keyed, ``@register_codec`` on the class, ``create()`` returns a fresh
stateful instance (delta chains live inside the instance, one per session).
"""
from __future__ import annotations

import abc
from typing import Any, Dict, Tuple

import numpy as np


class CodecError(Exception):
    """Base class for codec failures."""


class UnknownCodecError(CodecError, KeyError):
    def __init__(self, name: str):
        super().__init__(f"unknown codec {name!r}; available: {available()}")
        self.name = name


class CodecOrderError(CodecError):
    """A chained codec received a delta whose base has not been seen yet.

    Carries enough context for the staging server to *park* the dataset and
    retry once the base arrives (io_threads > 1 reorders write_reqs).
    """

    def __init__(self, key: str, base: int, have: int):
        super().__init__(
            f"chained decode out of order for {key!r}: need base seq {base}, "
            f"decoder is at seq {have}")
        self.key = key
        self.base = base
        self.have = have


# Numpy dtypes for the wire-level dtype strings used by write_req/SAVIME.
_DTYPES = {
    "double": np.float64, "float": np.float32, "float64": np.float64,
    "float32": np.float32, "float16": np.float16,
    "int64": np.int64, "int32": np.int32, "int16": np.int16,
    "int8": np.int8, "uint8": np.uint8, "char": np.uint8,
}


def np_dtype(dtype: str):
    """Map a wire dtype string to a numpy dtype, or None if unknown."""
    if dtype in _DTYPES:
        return np.dtype(_DTYPES[dtype])
    try:
        return np.dtype(dtype)
    except TypeError:
        return None


def as_bytes_array(data) -> np.ndarray:
    """View any bytes-like / ndarray input as a flat uint8 array (no copy)."""
    if isinstance(data, np.ndarray):
        a = np.ascontiguousarray(data)
        return a.view(np.uint8).reshape(-1)
    return np.frombuffer(memoryview(data).cast("B"), dtype=np.uint8)


class Codec(abc.ABC):
    """Encode/decode one dataset's bytes.

    Class attributes:
      name      registry key (set by ``@register_codec``).
      lossless  decode(encode(x)) is byte-identical to x.
      chained   encode output depends on the previous dataset of the same
                key (tar/dataset name); chained codecs must decode at ingest
                and in sequence order (``CodecOrderError`` signals a gap).

    Instances are stateful and single-session: one encoder per Communicator,
    one decoder per StagingServer.  ``meta`` must stay small and JSON-safe —
    it rides the write_req/stripe_open/batch_open control frame; bulk side
    data (e.g. scales) belongs inside the payload.
    """

    name: str = ""
    lossless: bool = True
    chained: bool = False

    @abc.abstractmethod
    def encode(self, data, *, dtype: str = "uint8",
               key: str = "") -> Tuple[Any, Dict[str, Any]]:
        """Return ``(payload, meta)``; payload is bytes-like/uint8 array."""

    @abc.abstractmethod
    def decode(self, payload, meta: Dict[str, Any], *,
               key: str = "") -> np.ndarray:
        """Return the raw bytes as a flat uint8 array."""

    def reset(self, key: str = "") -> None:
        """Forget any cross-dataset encoder state for ``key``.

        Called before a *replayed* write (journal recovery): a chained
        codec must emit a self-contained frame (``base=None``) because
        the peer's decode chain may or may not have seen the original.
        Stateless codecs need nothing — the default is a no-op."""


_REGISTRY: Dict[str, type] = {}


def register_codec(name: str):
    """Class decorator: ``@register_codec("delta-rle")``."""

    def deco(cls):
        cls.name = name
        _REGISTRY[name] = cls
        return cls

    return deco


def available() -> list:
    return sorted(_REGISTRY)


def get(name: str) -> type:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise UnknownCodecError(name) from None


def create(name: str, **kwargs) -> Codec:
    """Instantiate a fresh (stateful) codec by registry name."""
    return get(name)(**kwargs)
