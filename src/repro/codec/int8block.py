"""Lossy int8 block quantization: per-4096-element amax scales.

The same scheme as ``optim/grad_compress`` but applied to egress datasets:
each block of 4096 consecutive elements is scaled by ``amax/127`` and
rounded to int8, shrinking float64 payloads 8x (float32 4x) minus a 4-byte
scale per block.  The reconstruction error is provably bounded:
``|x - dq| <= scale/2`` per element (rint is within 1/2 ULP of ``x/scale``
and ``|x/scale| <= 127`` by construction, so the clip never bites).

For jax device arrays the quantize+pack step lowers through the
``kernels/staging_pack`` quantizing variant (``ops.quantize_blocks``) so
bytes shrink *on device* before the host copy; numpy inputs take an
equivalent host path.  Non-float dtypes pass through unchanged
(``meta["passthrough"]``) — lossy quantization of index data would be
silent corruption.
"""
from __future__ import annotations

from typing import Any, Dict, Tuple

import numpy as np

from .base import Codec, as_bytes_array, np_dtype, register_codec

BLOCK = 4096  # elements per scale block; matches grad_compress.QBLOCK


def _device_array(data):
    """Return data if it is a jax device array, else None (no jax import
    unless the input plausibly needs it)."""
    if isinstance(data, (np.ndarray, bytes, bytearray, memoryview)):
        return None
    try:
        import jax
    except Exception:  # pragma: no cover - jax is a hard dep in this repo
        return None
    return data if isinstance(data, jax.Array) else None


@register_codec("int8-block")
class Int8BlockCodec(Codec):
    """Per-block int8 quantization; payload = f32 scales || int8 values.

    ``impl`` selects the device lowering for jax-array inputs: ``"xla"``
    (default, runs everywhere and keeps CPU CI honest) or ``"pallas"``
    (the fused staging_pack kernel, TPU).  Host numpy inputs always take
    the vectorized numpy path.
    """

    lossless = False
    chained = False

    def __init__(self, impl: str = "xla"):
        self.impl = impl

    def encode(self, data, *, dtype: str = "uint8",
               key: str = "") -> Tuple[Any, Dict[str, Any]]:
        dev = _device_array(data)
        if dev is not None:
            return self._encode_device(dev)
        if isinstance(data, np.ndarray) and data.dtype != np.uint8:
            arr = np.ascontiguousarray(data)
        else:
            # bytes-like input (or a flat uint8 view, which is how the
            # Communicator ships every dataset): reinterpret through the
            # declared dataset dtype
            dt = np_dtype(dtype)
            raw = as_bytes_array(data)
            if dt is None or dt.itemsize == 0 or raw.size % dt.itemsize:
                return self._passthrough(raw)
            arr = raw.view(dt)
        if arr.dtype.kind != "f" or arr.dtype.itemsize < 2:
            return self._passthrough(as_bytes_array(arr))
        x = arr.reshape(-1)
        n = x.size
        nb = -(-n // BLOCK)
        scales = np.ones(nb, np.float32)
        q = np.empty(nb * BLOCK, np.int8)
        if n:
            # float16 math would wreck the scale/2 bound; compute in >=f32.
            cdt = x.dtype if x.dtype.itemsize >= 4 else np.dtype(np.float32)
            xb = np.zeros(nb * BLOCK, cdt)
            xb[:n] = x
            xb = xb.reshape(nb, BLOCK)
            amax = np.max(np.abs(xb), axis=1)
            scales = (amax / np.float32(127.0)).astype(np.float32)
            scales[scales == 0] = 1.0
            q = np.clip(np.rint(xb / scales[:, None].astype(cdt)),
                        -127, 127).astype(np.int8).reshape(-1)
        payload = scales.tobytes() + q[:n].tobytes()
        meta = {"raw_size": int(n * arr.dtype.itemsize), "n": int(n),
                "dtype": arr.dtype.name, "block": BLOCK}
        return payload, meta

    def _encode_device(self, x) -> Tuple[Any, Dict[str, Any]]:
        import jax.numpy as jnp
        if jnp.issubdtype(x.dtype, jnp.floating) and x.dtype.itemsize >= 2:
            from repro.kernels.staging_pack import ops
            q, scales = ops.quantize_blocks(x, block_elems=BLOCK,
                                            impl=self.impl)
            n = int(x.size)
            # np.asarray is the device->host copy: int8 + f32 scales, not
            # the full-width floats.
            qh = np.asarray(q).reshape(-1)[:n]
            sh = np.asarray(scales).astype(np.float32, copy=False)
            payload = sh.tobytes() + qh.tobytes()
            meta = {"raw_size": int(n * np.dtype(x.dtype).itemsize),
                    "n": n, "dtype": np.dtype(x.dtype).name, "block": BLOCK}
            return payload, meta
        return self._passthrough(as_bytes_array(np.asarray(x)))

    @staticmethod
    def _passthrough(raw: np.ndarray) -> Tuple[Any, Dict[str, Any]]:
        return raw, {"raw_size": int(raw.size), "passthrough": True}

    def decode(self, payload, meta: Dict[str, Any], *,
               key: str = "") -> np.ndarray:
        raw = as_bytes_array(payload)
        if meta.get("passthrough"):
            return raw
        n = int(meta["n"])
        block = int(meta.get("block", BLOCK))
        dt = np.dtype(meta["dtype"])
        nb = -(-n // block)
        if raw.size != nb * 4 + n:
            raise ValueError(
                f"int8-block payload is {raw.size}B, expected "
                f"{nb * 4 + n}B ({nb} scales + {n} values)")
        scales = raw[:nb * 4].view(np.float32)
        q = np.zeros(nb * block, np.int8)
        q[:n] = raw[nb * 4:].view(np.int8)
        cdt = dt if dt.itemsize >= 4 else np.dtype(np.float32)
        dq = (q.reshape(nb, block).astype(cdt) *
              scales[:, None].astype(cdt)).reshape(-1)[:n]
        return np.ascontiguousarray(dq.astype(dt)).view(np.uint8)
