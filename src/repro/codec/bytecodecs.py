"""Host-path byte codecs: ``none`` (identity) and ``delta-rle`` (lossless).

``delta-rle`` exploits temporal redundancy between successive datasets of
the same tar: iterative solvers rewrite mostly-unchanged grids every few
timesteps, so XOR against the previous payload is sparse and run-length
encodes well.  The RLE operates on 64-byte chunks (a zero *chunk* is the
unit of a run) so the encoder is a handful of vectorized numpy passes, not
a per-byte Python loop.
"""
from __future__ import annotations

import struct
from typing import Any, Dict, Tuple

import numpy as np

from .base import Codec, CodecOrderError, as_bytes_array, register_codec

_CHUNK = 64
_TOK = struct.Struct(">II")  # (zero_chunks, literal_chunks)


def _zrle_encode(buf: np.ndarray) -> bytes:
    """Run-length encode zero 64-byte chunks: [u32 z][u32 l][l*64 bytes]...

    Full chunks are tokenized; the sub-chunk tail is appended verbatim.
    """
    n = buf.size
    nc = n // _CHUNK
    parts = []
    if nc:
        head = buf[:nc * _CHUNK].reshape(nc, _CHUNK)
        zero = ~head.any(axis=1)
        edges = np.flatnonzero(np.diff(zero.view(np.int8))) + 1
        bounds = np.concatenate(([0], edges, [nc]))
        i = 0
        while i < len(bounds) - 1:
            a, b = int(bounds[i]), int(bounds[i + 1])
            if zero[a]:
                z, i = b - a, i + 1
                if i < len(bounds) - 1:
                    lb = int(bounds[i + 1])
                    parts.append(_TOK.pack(z, lb - b))
                    parts.append(head[b:lb].tobytes())
                    i += 1
                else:
                    parts.append(_TOK.pack(z, 0))
            else:
                parts.append(_TOK.pack(0, b - a))
                parts.append(head[a:b].tobytes())
                i += 1
    tail = buf[nc * _CHUNK:]
    if tail.size:
        parts.append(tail.tobytes())
    return b"".join(parts)


def _zrle_decode(payload, n: int) -> np.ndarray:
    out = np.zeros(n, np.uint8)
    mv = memoryview(payload).cast("B")
    nc = n // _CHUNK
    pos = off = done = 0
    while done < nc:
        z, l = _TOK.unpack_from(mv, pos)
        pos += _TOK.size
        off += z * _CHUNK
        done += z
        if l:
            nb = l * _CHUNK
            out[off:off + nb] = np.frombuffer(mv[pos:pos + nb], np.uint8)
            pos += nb
            off += nb
            done += l
    tail = n - nc * _CHUNK
    if tail:
        out[nc * _CHUNK:] = np.frombuffer(mv[pos:pos + tail], np.uint8)
        pos += tail
    if pos != len(mv):
        raise ValueError(f"zrle payload has {len(mv) - pos} trailing bytes")
    return out


@register_codec("none")
class NoneCodec(Codec):
    """Identity codec — the default.  Never selected on the hot path (the
    Communicator skips encoding entirely for ``codec="none"``); exists so
    the registry, negotiation, and benchmarks treat "no codec" uniformly."""

    lossless = True

    def encode(self, data, *, dtype: str = "uint8",
               key: str = "") -> Tuple[Any, Dict[str, Any]]:
        raw = as_bytes_array(data)
        return raw, {"raw_size": int(raw.size)}

    def decode(self, payload, meta: Dict[str, Any], *,
               key: str = "") -> np.ndarray:
        return as_bytes_array(payload)


@register_codec("delta-rle")
class DeltaRleCodec(Codec):
    """XOR-delta against the previous same-key dataset + zero-chunk RLE.

    Chained: dataset *i* can only be decoded after dataset *i-1* of the same
    key, so the staging server decodes at ingest and parks out-of-order
    arrivals.  A size change (or first dataset of a key) resets the chain
    (``base=None`` → self-contained RLE of the raw bytes).  If RLE would
    expand the payload (incompressible delta) the codec falls back to
    shipping the delta verbatim (``mode="raw"``) so output never exceeds
    input size.
    """

    lossless = True
    chained = True

    def __init__(self):
        # key -> (seq of last encoded/decoded dataset, its raw uint8 copy)
        self._enc: Dict[str, Tuple[int, np.ndarray]] = {}
        self._dec: Dict[str, Tuple[int, np.ndarray]] = {}

    def reset(self, key: str = "") -> None:
        # next encode of this key is self-contained (base=None, seq=0):
        # replay after a reconnect cannot assume the server's chain state
        self._enc.pop(key, None)

    def encode(self, data, *, dtype: str = "uint8",
               key: str = "") -> Tuple[Any, Dict[str, Any]]:
        raw = as_bytes_array(data)
        prev = self._enc.get(key)
        if prev is not None and prev[1].size == raw.size:
            base, delta = prev[0], np.bitwise_xor(raw, prev[1])
        else:
            base, delta = None, raw
        seq = (prev[0] + 1) if prev is not None else 0
        payload = _zrle_encode(delta)
        meta = {"raw_size": int(raw.size), "seq": seq, "base": base,
                "mode": "rle"}
        if len(payload) >= raw.size:
            payload, meta["mode"] = delta.tobytes(), "raw"
        self._enc[key] = (seq, raw.copy())
        return payload, meta

    def decode(self, payload, meta: Dict[str, Any], *,
               key: str = "") -> np.ndarray:
        n = int(meta["raw_size"])
        base, seq = meta.get("base"), int(meta["seq"])
        prev = self._dec.get(key)
        if base is not None:
            if prev is None or prev[0] != base:
                raise CodecOrderError(key, base, -1 if prev is None
                                      else prev[0])
            if prev[1].size != n:
                raise ValueError(
                    f"delta chain for {key!r} expects base of {n}B, "
                    f"have {prev[1].size}B")
        if meta.get("mode") == "raw":
            delta = as_bytes_array(payload).copy()
            if delta.size != n:
                raise ValueError(
                    f"raw delta for {key!r} is {delta.size}B, expected {n}B")
        else:
            delta = _zrle_decode(payload, n)
        raw = delta if base is None else np.bitwise_xor(delta, prev[1])
        self._dec[key] = (seq, raw)
        return raw
